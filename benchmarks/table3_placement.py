"""Table 3 (beyond-paper) — placement strategy trade-offs on a spot market.

Sweep: placement strategy {pack, spread} x a spot-heavy autoscaled cluster
running the small/medium Jacobi stream, several seeds each.  Per cell:

- idle dollars + time-averaged fragmentation: ``pack`` keeps nodes either
  full or empty, so the autoscaler can retire whole nodes (low idle-$, low
  fragmentation); ``spread`` strands free slots on partially-used nodes
  until a drain migrates the residents off (high idle-$, high frag).
- kill blast radius (mean displaced slots PER RESIDENT JOB per spot kill):
  ``pack`` concentrates a job on few nodes, so one reclaim takes a large
  bite out of few jobs (big radius, more checkpoint-preemptions);
  ``spread`` dilutes each job across nodes, so a reclaim nicks many jobs
  slightly — usually absorbed by an in-place shrink (small radius).

The verdict row checks exactly that trade-off: pack must win idle-$,
spread must win blast radius.
"""
import time

from benchmarks.common import emit, phases_kv
from repro.cloud import (SPOT, AutoscalerConfig, CloudProvider, CloudSimulator,
                         NodeAutoscaler, NodePool)
from repro.core.autoscale import PreemptingPolicy
from repro.core.policies import PolicyConfig
from repro.core.simulator import jacobi_workload, make_jacobi_jobs

SLOTS_PER_NODE = 8
PRICE_OD = 0.048
PRICE_SPOT = 0.016
# 10 seeds: the idle-$ gap is a ~20% effect over noisy per-seed values
# (spread occasionally drains a node early), and the fast-lane rescale costs
# shifted completion timings enough that 5 seeds no longer separate the means
SEEDS = (7, 11, 23, 31, 43, 3, 17, 59, 71, 97)
# 20 s gaps keep many jobs in flight at once (placement only discriminates
# under concurrency: a serial stream parks one job per cluster)
SUBMISSION_GAP = 20.0
SPOT_LIFETIME = 600.0          # mean node life ~ run length: kills DO land


def run_cell(placement: str, seed: int):
    specs = make_jacobi_jobs(seed=seed, n_jobs=16,
                             submission_gap=SUBMISSION_GAP,
                             sizes=("small", "medium"))
    prov = CloudProvider([
        NodePool("od", slots_per_node=SLOTS_PER_NODE,
                 price_per_slot_hour=PRICE_OD, boot_latency=120.0,
                 teardown_delay=30.0, initial_nodes=1, max_nodes=4),
        NodePool("spot", slots_per_node=SLOTS_PER_NODE,
                 price_per_slot_hour=PRICE_SPOT, market=SPOT,
                 boot_latency=90.0, teardown_delay=30.0, initial_nodes=2,
                 max_nodes=6, spot_lifetime_mean=SPOT_LIFETIME),
    ], seed=seed)
    pcfg = PolicyConfig(rescale_gap=180.0)
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=30.0, scale_up_cooldown=30.0, scale_down_cooldown=60.0,
        idle_timeout=120.0, spot_fraction=0.5))
    sim = CloudSimulator(prov, pcfg, policy=PreemptingPolicy(pcfg),
                         autoscaler=asc, placement=placement)
    for s in specs:
        sim.submit(s, jacobi_workload(s.workload))
    return sim.run()


def _mean(xs):
    return sum(xs) / len(xs)


def run():
    agg = {}
    for placement in ("pack", "spread"):
        cells = []
        t0 = time.perf_counter()
        for seed in SEEDS:
            cells.append(run_cell(placement, seed))
        us = (time.perf_counter() - t0) * 1e6 / len(SEEDS)
        agg[placement] = dict(
            cost=_mean([m.total_cost for m in cells]),
            idle=_mean([m.idle_cost for m in cells]),
            frag=_mean([m.avg_fragmentation for m in cells]),
            blast=_mean([m.kill_blast_radius for m in cells]),
            blast_jobs=_mean([m.kill_blast_jobs for m in cells]),
            preempts=_mean([m.kill_preemptions for m in cells]),
            compl=_mean([m.weighted_mean_completion for m in cells]),
            kills=_mean([m.spot_preemptions for m in cells]),
            dropped=sum(m.dropped_jobs for m in cells),
        )
        a = agg[placement]
        emit(f"table3.{placement}", us,
             f"cost={a['cost']:.4f};idle={a['idle']:.4f};"
             f"frag={a['frag']:.3f};blast={a['blast']:.2f};"
             f"blast_jobs={a['blast_jobs']:.2f};preempts={a['preempts']:.2f};"
             f"compl={a['compl']:.1f};kills={a['kills']:.1f};"
             f"dropped={a['dropped']}")
        emit(f"table3.{placement}.phases", 0.0, phases_kv(cells))

    pack, spread = agg["pack"], agg["spread"]
    ok = (pack["idle"] < spread["idle"]
          and spread["blast"] < pack["blast"]
          and pack["dropped"] == 0 and spread["dropped"] == 0)
    emit("table3.verdict.pack_vs_spread", 0.0,
         f"idle_pack={pack['idle']:.4f}<idle_spread={spread['idle']:.4f};"
         f"blast_spread={spread['blast']:.2f}<blast_pack={pack['blast']:.2f};"
         f"frag_pack={pack['frag']:.3f};frag_spread={spread['frag']:.3f};"
         f"{'PASS' if ok else 'FAIL'}")
    return agg


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
