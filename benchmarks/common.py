"""Shared benchmark utilities: CSV emission per the harness contract
(``name,us_per_call,derived``)."""
import sys
import time


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()


def kv(*fragments: str, **fields) -> str:
    """Build a derived-field string: ``k=v;...``.  Floats render compactly;
    string ``fragments`` (e.g. a WorkloadStats.kv()) are spliced in as-is so
    characterization columns ride along with metric columns."""
    parts = [f for f in fragments if f]
    for k, v in fields.items():
        parts.append(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}")
    return ";".join(parts)


def flat_metrics(m) -> dict:
    """Flatten ``ScheduleMetrics.to_dict()``: dict-valued fields become
    dotted keys (``percentiles.resp_p99``, ``counters.events``)."""
    out = {}
    for k, v in m.to_dict().items():
        if isinstance(v, dict):
            for k2, v2 in v.items():
                out[f"{k}.{k2}"] = v2
        else:
            out[k] = v
    return out


def metrics_kv(m, *keys, prefixes=(), **extra) -> str:
    """Derived-field string straight from a :class:`ScheduleMetrics`:
    ``keys`` name flat fields to emit (missing keys are skipped — a
    fixed-capacity run has no ``percentiles.resp_p99_prio5`` until a
    priority-5 job completes); ``prefixes`` pull every flat key under a
    dotted prefix (e.g. ``percentiles.resp_p99`` matches the aggregate and
    each priority class).  Output names drop the dict-field prefix."""
    flat = flat_metrics(m)
    fields = {}
    for k in keys:
        if k in flat:
            fields[k.split(".", 1)[-1]] = flat[k]
    for p in prefixes:
        for k in sorted(flat):
            if k.startswith(p):
                fields[k.split(".", 1)[-1]] = flat[k]
    fields.update(extra)
    return kv(**fields)


def phases_kv(cells) -> str:
    """Derived-field string of mean per-phase seconds (the priority-weighted
    makespan decomposition from ``repro.obs.critical_path``) over one or more
    :class:`ScheduleMetrics` — the ``.phases`` row every table emits next to
    its headline numbers.  Empty string when no cell carries phases."""
    ms = cells if isinstance(cells, (list, tuple)) else [cells]
    ms = [m for m in ms if getattr(m, "phase_seconds", None)]
    if not ms:
        return ""
    acc = {}
    for m in ms:
        for k, v in m.phase_seconds.items():
            acc[k] = acc.get(k, 0.0) + v
    n = len(ms)
    return kv(**{k: v / n for k, v in acc.items()})


def time_call(fn, *args, repeat: int = 3, **kw):
    """Median wall time in microseconds."""
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]
