"""Shared benchmark utilities: CSV emission per the harness contract
(``name,us_per_call,derived``)."""
import sys
import time


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()


def kv(*fragments: str, **fields) -> str:
    """Build a derived-field string: ``k=v;...``.  Floats render compactly;
    string ``fragments`` (e.g. a WorkloadStats.kv()) are spliced in as-is so
    characterization columns ride along with metric columns."""
    parts = [f for f in fragments if f]
    for k, v in fields.items():
        parts.append(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}")
    return ";".join(parts)


def time_call(fn, *args, repeat: int = 3, **kw):
    """Median wall time in microseconds."""
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]
