"""Shared benchmark utilities: CSV emission per the harness contract
(``name,us_per_call,derived``)."""
import sys
import time


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()


def time_call(fn, *args, repeat: int = 3, **kw):
    """Median wall time in microseconds."""
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]
