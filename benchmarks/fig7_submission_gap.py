"""Paper Fig. 7 — scheduler metrics vs. job submission gap (4 policies,
averaged over seeds; 64 slots, 16 jobs, T_rescale_gap=180 s)."""
import numpy as np

from benchmarks.common import emit, time_call


def run(seeds=range(12), gaps=(0, 30, 60, 90, 120, 180, 240, 300)):
    from repro.core.simulator import VARIANTS, make_jacobi_jobs, run_variant

    for gap in gaps:
        for v in VARIANTS:
            rows = []
            us = 0.0
            for seed in seeds:
                specs = make_jacobi_jobs(seed=seed, n_jobs=16,
                                         submission_gap=float(gap))
                import time
                t0 = time.perf_counter()
                m = run_variant(v, specs, total_slots=64, rescale_gap=180.0)
                us += (time.perf_counter() - t0) * 1e6
                rows.append([m.total_time, m.utilization,
                             m.weighted_mean_response,
                             m.weighted_mean_completion])
            a = np.mean(rows, axis=0)
            emit(f"fig7.gap{gap}.{v}", us / len(list(seeds)),
                 f"total={a[0]:.0f};util={a[1]:.3f};resp={a[2]:.1f};"
                 f"compl={a[3]:.1f}")
