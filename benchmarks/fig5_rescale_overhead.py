"""Paper Fig. 5 — rescale overhead decomposed into the four stages
(load-balance / checkpoint / restart / restore).

(a) REAL measurements: ElasticTrainer shrink/expand on virtual devices
    (subprocess, 8 devices) across replica counts and model sizes — the JAX
    analog of the paper's Jacobi runs, including the paper's headline
    findings (restart dominates small problems; in-memory ckpt/restore cheap).
(b) The calibrated analytic model the simulator uses (paper shapes 5a/5b/5c).
(c) Per-phase makespan decomposition of traced simulator runs — where the
    overhead of (a)/(b) actually lands in end-to-end completion time — with
    a reconciliation PASS/FAIL row: the phase sums must match the
    priority-weighted mean completion to <0.1% (same invariant the trace
    auditor enforces).

``run(sim_only=True)`` (the harness ``--fast`` path / CI) skips the live
subprocess section (a) and keeps (b) and (c).
"""
import json
import os
import subprocess
import sys

from benchmarks.common import emit, kv, phases_kv

HELPER = r"""
import json, sys
import jax
from repro.configs import smoke_config
from repro.core.elastic import ElasticTrainer, TrainJobConfig

devs = jax.devices()
out = []
for arch, width in [("yi-6b", 64), ("yi-6b", 128)]:
    cfg = smoke_config(arch).with_(d_model=width, expected_params=0.0)
    for r0, r1 in [(4, 2), (2, 4), (8, 4), (4, 8)]:
        tr = ElasticTrainer(cfg, TrainJobConfig(global_batch=8, seq_len=32,
                                                total_steps=4, seed=0),
                            devs[:r0])
        tr.step()
        t = tr.rescale(devs[:r1])
        out.append(dict(width=width, r0=r0, r1=r1, **t.as_dict()))
print("JSON" + json.dumps(out))
"""


def _live_rows():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath("src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", HELPER],
                          capture_output=True, text=True, timeout=1800,
                          env=env)
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("JSON"):
            rows = json.loads(line[4:])
    for r in rows:
        kind = "shrink" if r["r1"] < r["r0"] else "expand"
        name = f"fig5.live.{kind}.w{r['width']}.{r['r0']}to{r['r1']}"
        emit(name, r["total"] * 1e6,
             f"lb={r['load_balance']:.3f};ckpt={r['checkpoint']:.3f};"
             f"restart={r['restart']:.3f};restore={r['restore']:.3f}")
    if not rows:
        emit("fig5.live.FAILED", 0.0, proc.stderr[-200:].replace(",", ";"))


def _sim_phase_rows():
    """(c): decompose traced end-to-end runs into the obs phase partition
    and assert the decomposition reconciles with the makespan metric."""
    from repro.core.simulator import make_jacobi_jobs, run_variant
    from repro.obs.critical_path import reconcile
    from repro.obs.trace import Tracer, current_tracer, install

    outer = current_tracer()             # harness --trace file, if any
    for variant in ("elastic", "elastic_preempt"):
        specs = make_jacobi_jobs(seed=7, n_jobs=16, submission_gap=90.0)
        with Tracer() as tr, install(tr):
            m = run_variant(variant, specs, total_slots=64,
                            rescale_gap=180.0)
        if outer.enabled:                # tee so fig5.jsonl stays auditable
            for r in tr.records:
                outer.emit(r["kind"], r["t"],
                           **{k: v for k, v in r.items()
                              if k not in ("kind", "t")})
        emit(f"fig5.sim.{variant}.phases", 0.0, phases_kv(m))
        violations = reconcile(tr.records, rel_tol=1e-3)
        total = sum(m.phase_seconds.values())
        drift = abs(total - m.weighted_mean_completion)
        emit(f"fig5.sim.{variant}.phase_reconcile", 0.0, kv(
            "PASS" if not violations else "FAIL",
            phase_total=total, wmct=m.weighted_mean_completion,
            drift_s=drift, violations=len(violations)))


def run(sim_only: bool = False):
    if not sim_only:
        _live_rows()

    # analytic model (paper Fig. 5a/5b/5c shapes)
    from repro.core.perf_model import RescaleModel
    rm = RescaleModel()
    for p in (4, 8, 16, 32, 64):                      # 5a: shrink p -> p/2
        st = rm.stages(p, p // 2, 2 * 4.0 * 8192 ** 2)
        emit(f"fig5.model.shrink_half.p{p}", sum(st.values()) * 1e6,
             ";".join(f"{k}={v:.3f}" for k, v in st.items()))
    for p in (4, 8, 16, 32):                          # 5b: expand p -> 2p
        st = rm.stages(p, 2 * p, 2 * 4.0 * 8192 ** 2)
        emit(f"fig5.model.expand_double.p{p}", sum(st.values()) * 1e6,
             ";".join(f"{k}={v:.3f}" for k, v in st.items()))
    for n in (1024, 4096, 8192, 16384, 23000):        # 5c: 32 -> 16, size sweep
        st = rm.stages(32, 16, 2 * 4.0 * n ** 2)
        emit(f"fig5.model.shrink32to16.n{n}", sum(st.values()) * 1e6,
             ";".join(f"{k}={v:.3f}" for k, v in st.items()))

    _sim_phase_rows()
