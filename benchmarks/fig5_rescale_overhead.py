"""Paper Fig. 5 — rescale overhead decomposed into the four stages
(load-balance / checkpoint / restart / restore).

(a) REAL measurements: ElasticTrainer shrink/expand on virtual devices
    (subprocess, 8 devices) across replica counts and model sizes — the JAX
    analog of the paper's Jacobi runs, including the paper's headline
    findings (restart dominates small problems; in-memory ckpt/restore cheap).
(b) The calibrated analytic model the simulator uses (paper shapes 5a/5b/5c).
(c) Per-phase makespan decomposition of traced simulator runs — where the
    overhead of (a)/(b) actually lands in end-to-end completion time — with
    a reconciliation PASS/FAIL row: the phase sums must match the
    priority-weighted mean completion to <0.1% (same invariant the trace
    auditor enforces).

``run(sim_only=True)`` (the harness ``--fast`` path / CI) skips the live
subprocess section (a) and keeps (b) and (c).
"""
import json
import os
import subprocess
import sys

from benchmarks.common import emit, kv, phases_kv

HELPER = r"""
import json
import jax
from repro.configs import smoke_config
from repro.core.elastic import ElasticTrainer, TrainJobConfig

devs = jax.devices()
out = []
for arch, width in [("yi-6b", 64), ("yi-6b", 128)]:
    cfg = smoke_config(arch).with_(d_model=width, expected_params=0.0)
    for r0, r1 in [(4, 2), (2, 4), (8, 4), (4, 8)]:
        tr = ElasticTrainer(cfg, TrainJobConfig(global_batch=8, seq_len=32,
                                                total_steps=4, seed=0),
                            devs[:r0])
        tr.step()
        t = tr.rescale(devs[:r1], via_host=True)      # legacy host path
        out.append(dict(width=width, r0=r0, r1=r1, path="host",
                        **t.as_dict()))
        tr.rescale(devs[:r0], via_host=True)          # back; r1 now warm
        t = tr.rescale(devs[:r1])                     # fast: auto p2p + warm
        out.append(dict(width=width, r0=r0, r1=r1, path=t.path,
                        **t.as_dict()))
print("JSON" + json.dumps(out))
"""

KERNEL_HELPER = r"""
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint.reshard import snapshot_to_host
from repro.kernels.pack import packed_snapshot_to_host

# on CPU the Pallas kernel runs in interpret mode (Python-speed, validation
# only); the packed-vs-perleaf ratio is meaningful on a real TPU backend
mode = "compiled" if jax.default_backend() == "tpu" else "interpret"
rng = np.random.default_rng(0)
def tree_of(n_leaves, leaf_elems):
    return {f"layer{i:02d}": {"w": jnp.asarray(
        rng.standard_normal(leaf_elems).astype(np.float32))}
        for i in range(n_leaves)}

out = []
for n_leaves, leaf_elems in [(16, 4096), (64, 4096), (64, 65536)]:
    tree = tree_of(n_leaves, leaf_elems)
    for name, fn in [("perleaf", lambda t: snapshot_to_host(t)),
                     ("packed", lambda t: packed_snapshot_to_host(t))]:
        fn(tree)                                    # warm (trace/compile)
        t0 = time.perf_counter(); reps = 3
        for _ in range(reps):
            fn(tree)
        dt = (time.perf_counter() - t0) / reps
        out.append(dict(kind=name, leaves=n_leaves, elems=leaf_elems,
                        seconds=dt, mode=mode))
print("JSON" + json.dumps(out))
"""


def _helper_rows(code: str, tag: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath("src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=1800,
                          env=env)
    for line in proc.stdout.splitlines():
        if line.startswith("JSON"):
            return json.loads(line[4:])
    emit(f"fig5.{tag}.FAILED", 0.0, proc.stderr[-200:].replace(",", ";"))
    return []


def _live_rows():
    for r in _helper_rows(HELPER, "live"):
        kind = "shrink" if r["r1"] < r["r0"] else "expand"
        name = (f"fig5.live.{kind}.w{r['width']}.{r['r0']}to{r['r1']}"
                f".{r['path']}")
        emit(name, r["total"] * 1e6,
             f"lb={r['load_balance']:.3f};ckpt={r['checkpoint']:.3f};"
             f"restart={r['restart']:.3f};restore={r['restore']:.3f}")


def _kernel_rows():
    """Slow-lane fig5 kernel section: fused Pallas pack vs. per-leaf
    device_get for the device->host snapshot (grounds the fast-lane
    reshard-bandwidth constants)."""
    rows = _helper_rows(KERNEL_HELPER, "kernel")
    by_case = {}
    mode = rows[0]["mode"] if rows else "?"
    for r in rows:
        name = f"fig5.kernel.snapshot.{r['kind']}.l{r['leaves']}x{r['elems']}"
        emit(name, r["seconds"] * 1e6,
             f"leaves={r['leaves']};elems={r['elems']};mode={r['mode']}")
        by_case.setdefault((r["leaves"], r["elems"]), {})[r["kind"]] = \
            r["seconds"]
    for (leaves, elems), d in sorted(by_case.items()):
        if "perleaf" in d and "packed" in d:
            emit(f"fig5.kernel.pack_speedup.l{leaves}x{elems}", 0.0,
                 kv(f"{d['perleaf'] / d['packed']:.2f}x",
                    perleaf_s=d["perleaf"], packed_s=d["packed"], mode=mode))


def _sim_phase_rows():
    """(c): decompose traced end-to-end runs into the obs phase partition
    and assert the decomposition reconciles with the makespan metric."""
    from repro.core.simulator import make_jacobi_jobs, run_variant
    from repro.obs.critical_path import reconcile
    from repro.obs.trace import Tracer, current_tracer, install

    outer = current_tracer()             # harness --trace file, if any
    for variant in ("elastic", "elastic_preempt"):
        specs = make_jacobi_jobs(seed=7, n_jobs=16, submission_gap=90.0)
        with Tracer() as tr, install(tr):
            m = run_variant(variant, specs, total_slots=64,
                            rescale_gap=180.0)
        if outer.enabled:                # tee so fig5.jsonl stays auditable
            for r in tr.records:
                outer.emit(r["kind"], r["t"],
                           **{k: v for k, v in r.items()
                              if k not in ("kind", "t")})
        emit(f"fig5.sim.{variant}.phases", 0.0, phases_kv(m))
        violations = reconcile(tr.records, rel_tol=1e-3)
        total = sum(m.phase_seconds.values())
        drift = abs(total - m.weighted_mean_completion)
        emit(f"fig5.sim.{variant}.phase_reconcile", 0.0, kv(
            "PASS" if not violations else "FAIL",
            phase_total=total, wmct=m.weighted_mean_completion,
            drift_s=drift, violations=len(violations)))


def run(sim_only: bool = False):
    if not sim_only:
        _live_rows()
        _kernel_rows()

    # analytic model (paper Fig. 5a/5b/5c shapes), fast lane (the default
    # the simulator prices) + legacy (paper-faithful synchronous path), and
    # the gating verdict: fast lane must cut every sweep point >=5x
    from repro.core.perf_model import RescaleModel
    sweeps = ([("shrink_half", f"p{p}", p, p // 2, 2 * 4.0 * 8192 ** 2)
               for p in (4, 8, 16, 32, 64)]            # 5a: shrink p -> p/2
              + [("expand_double", f"p{p}", p, 2 * p, 2 * 4.0 * 8192 ** 2)
                 for p in (4, 8, 16, 32)]              # 5b: expand p -> 2p
              + [("shrink32to16", f"n{n}", 32, 16, 2 * 4.0 * n ** 2)
                 for n in (1024, 4096, 8192, 16384, 23000)])  # 5c: size sweep
    fast, legacy = RescaleModel(), RescaleModel(fast_lane=False)
    worst = None
    for sweep, pt, r0, r1, nbytes in sweeps:
        st = fast.stages(r0, r1, nbytes)
        st_l = legacy.stages(r0, r1, nbytes)
        emit(f"fig5.model.{sweep}.{pt}", sum(st.values()) * 1e6,
             ";".join(f"{k}={v:.3f}" for k, v in st.items()))
        emit(f"fig5.model_legacy.{sweep}.{pt}", sum(st_l.values()) * 1e6,
             ";".join(f"{k}={v:.3f}" for k, v in st_l.items()))
        ratio = sum(st_l.values()) / sum(st.values())
        if worst is None or ratio < worst[0]:
            worst = (ratio, f"{sweep}.{pt}")
    emit("fig5.verdict.fastlane_speedup", 0.0, kv(
        "PASS" if worst[0] >= 5.0 else "FAIL",
        min_ratio=round(worst[0], 2), at=worst[1], points=len(sweeps)))

    _sim_phase_rows()
