"""Paper Fig. 6 — per-iteration timeline across a shrink and an expand.

Real run on virtual devices: iteration times rise after shrink, fall after
expand; the rescale gaps are the measured overheads.
"""
import json
import os
import subprocess
import sys

from benchmarks.common import emit

HELPER = r"""
import json, time
import jax
from repro.configs import smoke_config
from repro.core.elastic import ElasticTrainer, TrainJobConfig

devs = jax.devices()
cfg = smoke_config("yi-6b").with_(d_model=128, num_layers=4, expected_params=0.0)
tr = ElasticTrainer(cfg, TrainJobConfig(global_batch=8, seq_len=64,
                                        total_steps=30, seed=0), devs[:4])
events = []
def run_steps(n):
    for _ in range(n):
        t0 = time.perf_counter()
        tr.step()
        events.append(("step", tr.replicas, time.perf_counter() - t0))
run_steps(8)
t = tr.rescale(devs[:2])
events.append(("shrink", 2, t.total))
run_steps(8)
t = tr.rescale(devs[:4])
events.append(("expand", 4, t.total))
run_steps(8)
print("JSON" + json.dumps(events))
"""


def run():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath("src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", HELPER],
                          capture_output=True, text=True, timeout=1800,
                          env=env)
    events = []
    for line in proc.stdout.splitlines():
        if line.startswith("JSON"):
            events = json.loads(line[4:])
    if not events:
        emit("fig6.timeline.FAILED", 0.0, proc.stderr[-200:].replace(",", ";"))
        return
    phase, buf = 0, []
    for kind, replicas, dt in events:
        if kind == "step":
            buf.append(dt)
        else:
            emit(f"fig6.phase{phase}.steps.r{buf and len(buf)}",
                 1e6 * sum(buf) / len(buf), f"replicas_before={replicas}")
            emit(f"fig6.{kind}", dt * 1e6, f"to_replicas={replicas}")
            phase += 1
            buf = []
    if buf:
        emit(f"fig6.phase{phase}.steps", 1e6 * sum(buf) / len(buf), "")
    # render the measured run as a flight-recorder timeline (stderr keeps
    # the stdout CSV clean); the trace records mirror what a live tracer
    # would have emitted for this one-job shrink/expand story
    print(_timeline(events), file=sys.stderr)


def _timeline(events) -> str:
    """Rebuild trace records from the helper's (kind, replicas, dt) events
    and render them with the shared Gantt renderer."""
    from repro.obs.timeline import render
    records = [{"kind": "run_start", "t": 0.0, "run": 1, "slots": 4},
               {"kind": "job_start", "t": 0.0, "job": "fig6-job",
                "slots": 4, "priority": 1, "resume": False}]
    t, replicas = 0.0, 4
    for kind, to_replicas, dt in events:
        t += dt
        if kind in ("shrink", "expand"):
            records.append({"kind": "job_rescale", "t": t, "job": "fig6-job",
                            "from": replicas, "to": to_replicas,
                            "overhead_s": dt})
            replicas = to_replicas
    records.append({"kind": "job_complete", "t": t, "job": "fig6-job",
                    "slots": replicas})
    records.append({"kind": "run_end", "t": t, "run": 1})
    return render(records, width=60)
